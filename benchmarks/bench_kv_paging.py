"""Paged vs contiguous KV layout — admission capacity at equal cache bytes,
decode throughput overhead of the block-table indirection, demand-paged
(lazy) vs upfront block allocation, and the kv_restore recovery decision.

The contiguous layout pins ``max_len`` KV rows per slot, so a mixed-length
workload admits at most ``max_batch`` requests no matter how short they
are. The paged layout spends the SAME cache bytes on a shared block pool
and admits until the pool (not the slot count) is exhausted — the memory
lever that lets heterogeneous stages run the large batches the roofline
estimator assumes. Demand paging stacks on top: admission books worst-case
need only as a LEDGER reservation (overcommittable) and allocates blocks
as decode actually writes them, so generation headroom stops stranding
pool capacity. check_smoke.py enforces:

  * paged admits >= 1.5x the concurrent mixed-length requests of contig at
    equal cache bytes;
  * lazy (demand-paged, overcommitted ledger) admits >= 1.2x the
    concurrent mixed-length requests of upfront reservation at equal pool
    bytes, with byte-identical greedy outputs across the grow and
    preempt/re-admit paths;
  * paged decode tok/s >= 0.8x contig at the same batch (the block-table
    gather must not cost more than 20%);
  * recovery ``decide()`` picks kv_restore over recompute when the store
    holds the request's blocks.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Dict

import jax
import numpy as np

from benchmarks.common import Rows, save_json
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, ServeRequest

MAX_LEN = 64
BLOCK = 8
EQ_BATCH = 8            # contig slots; paged gets the same bytes instead
MAX_NEW = 4
MAX_NEW_LAZY = 24       # generation headroom upfront reservation strands
LAZY_OVERCOMMIT = 2.0


def _workload(cfg, n: int, seed: int, max_new: int = MAX_NEW):
    rng = np.random.RandomState(seed)
    lens = rng.randint(4, 29, size=n)
    return [ServeRequest(
        prompt=rng.randint(0, cfg.vocab, size=int(ln)).tolist(),
        max_new_tokens=max_new) for ln in lens]


def _throughput(cfg, params, layout: str) -> Dict:
    """Equal-batch A/B: the paged indirection's decode overhead."""
    eng = Engine(cfg, params, max_batch=EQ_BATCH, max_len=MAX_LEN,
                 kv_layout=layout, block_size=BLOCK)
    reqs = _workload(cfg, EQ_BATCH, seed=5)
    t0 = time.perf_counter()
    admitted = eng.admit_many(reqs)
    t_admit = time.perf_counter() - t0
    assert len(admitted) == EQ_BATCH
    t0 = time.perf_counter()
    eng.drain()
    t_decode = time.perf_counter() - t0
    dec_toks = eng.stats.tokens_out - EQ_BATCH
    return {"layout": layout, "admit_s": t_admit, "decode_s": t_decode,
            "decode_tok_s": dec_toks / max(t_decode, 1e-9),
            "block_stats": eng.block_stats()}


def _capacity(cfg, params) -> Dict:
    """Max concurrently-admitted mixed-length requests at EQUAL cache
    bytes: contig = EQ_BATCH slots x MAX_LEN rows; paged = the same token
    capacity as a shared pool, slots no longer the limit."""
    pool_tokens = EQ_BATCH * MAX_LEN
    n_blocks = pool_tokens // BLOCK + 1           # +1 trash block
    contig = Engine(cfg, params, max_batch=EQ_BATCH, max_len=MAX_LEN,
                    kv_layout="contig")
    n_contig = len(contig.admit_many(_workload(cfg, 64, seed=9)))
    paged = Engine(cfg, params, max_batch=64, max_len=MAX_LEN,
                   kv_layout="paged", block_size=BLOCK, n_blocks=n_blocks)
    n_paged = len(paged.admit_many(_workload(cfg, 64, seed=9)))
    stats = paged.block_stats()
    return {"contig_admitted": n_contig, "paged_admitted": n_paged,
            "ratio": n_paged / max(n_contig, 1),
            "alloc_failures": paged.stats.alloc_failures,
            "frag_tokens": stats["frag_tokens"],
            "blocks_in_use": stats["blocks_in_use"]}


def _lazy_ab(cfg, params) -> Dict:
    """Demand-paged vs upfront allocation at EQUAL pool bytes: upfront
    books worst-case ``ceil((ctx + max_new)/block)`` blocks at admission;
    lazy books the same worst case only in the (overcommitted) ledger and
    allocates prefill blocks, growing on demand and preempting through the
    KV-export path when the pool runs dry. Outputs must stay byte-identical
    either way."""
    pool_tokens = EQ_BATCH * MAX_LEN
    n_blocks = pool_tokens // BLOCK + 1           # +1 trash block
    out: Dict = {}
    results: Dict[str, Dict[int, list]] = {}
    for mode, oc in (("upfront", 1.0), ("lazy", LAZY_OVERCOMMIT)):
        eng = Engine(cfg, params, max_batch=48, max_len=MAX_LEN,
                     kv_layout="paged", block_size=BLOCK, n_blocks=n_blocks,
                     kv_alloc=mode, kv_overcommit=oc)
        reqs = _workload(cfg, 48, seed=11, max_new=MAX_NEW_LAZY)
        admitted = eng.admit_many(reqs)
        concurrent = len(admitted)
        taken = {id(r) for r in admitted}
        queue = [r for r in reqs if id(r) not in taken]
        rounds = 0
        while (queue or eng.active() or eng._pending
               or eng._preempted) and rounds < 10_000:
            eng.step()
            if queue:
                adm = eng.admit_many(queue)
                taken = {id(r) for r in adm}
                queue = [r for r in queue if id(r) not in taken]
            rounds += 1
        assert all(r.done for r in reqs), f"{mode}: drain did not finish"
        assert eng.bm.check_no_leak()
        results[mode] = {i: list(r.generated) for i, r in enumerate(reqs)}
        out[mode] = {"concurrent": concurrent,
                     "preemptions": eng.stats.preemptions,
                     "block_grows": eng.stats.block_grows,
                     "peak_blocks": eng.bm.peak_blocks}
    out["ratio"] = out["lazy"]["concurrent"] \
        / max(out["upfront"]["concurrent"], 1)
    out["identical"] = results["lazy"] == results["upfront"]
    return out


def _recovery_decision() -> Dict:
    """decide() must pick kv_restore over (chunked) recompute when the
    tensor store holds the interrupted request's blocks."""
    from repro.cluster.recovery import decide
    from repro.core import populate_cluster
    from repro.hw import AWS_INSTANCES, effective, paper_cluster
    spec = get_config("llama-3.1-70b").to_modelspec()
    insts = {n: dataclasses.replace(i, device=effective(i.device))
             for n, i in AWS_INSTANCES.items()}
    plan = populate_cluster(spec, paper_cluster(), insts, 763, 232,
                            beam_k=1)
    p = plan.pipelines[0]
    d = decide(spec, p, ctx=4096, remaining_grace_s=120.0, policy="hybrid",
               efficiency=0.05, chunk=16, store_has_kv=True)
    return {"mechanism": d.mechanism,
            "kv_restore": 1.0 if d.mechanism == "kv_restore" else 0.0,
            "kv_restore_s": d.kv_restore_s, "recompute_s": d.recompute_s,
            "transfer_s": d.transfer_s}


def run(rows: Rows) -> Dict:
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    out: Dict = {}
    for layout in ("contig", "paged"):
        r = _throughput(cfg, params, layout)
        out[layout] = r
        rows.add(f"kv_paging/{layout}/decode", r["decode_s"] * 1e6,
                 f"tok_s={r['decode_tok_s']:.0f} "
                 f"admit_s={r['admit_s']:.3f}")
    cap = _capacity(cfg, params)
    out["capacity"] = cap
    rows.add("kv_paging/capacity", 0.0,
             f"contig={cap['contig_admitted']} "
             f"paged={cap['paged_admitted']} ratio={cap['ratio']:.2f}x "
             f"frag_tokens={cap['frag_tokens']} "
             f"alloc_failures={cap['alloc_failures']}")
    lazy = _lazy_ab(cfg, params)
    out["lazy_ab"] = lazy
    rows.add("kv_paging/lazy_capacity", 0.0,
             f"upfront={lazy['upfront']['concurrent']} "
             f"lazy={lazy['lazy']['concurrent']} "
             f"ratio={lazy['ratio']:.2f}x "
             f"preemptions={lazy['lazy']['preemptions']} "
             f"grows={lazy['lazy']['block_grows']} "
             f"identical={1 if lazy['identical'] else 0}")
    dec = _recovery_decision()
    out["recovery"] = dec
    rows.add("kv_paging/recovery_decide", 0.0,
             f"kv_restore={dec['kv_restore']:.0f} "
             f"kv_s={dec['kv_restore_s']:.2f} rc_s={dec['recompute_s']:.2f} "
             f"tr_s={dec['transfer_s']:.2f} mech={dec['mechanism']}")
    save_json("kv_paging", out)
    return out
