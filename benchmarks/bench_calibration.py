"""Paper Table 4 / §7.1.5 — one-time calibration overhead.

Runs the actual GEMM / GEMV / AllReduce microbenchmarks on the local device
and reports wall time per stage (the paper: 1022s over 3 GPU types = 0.03%
of its evaluation's GPU-hours)."""

from __future__ import annotations

import time
from typing import Dict

from benchmarks.common import Rows, save_json
from repro.hw.calibration import (calibrate_allreduce, calibrate_gemm,
                                  calibrate_gemv)


def run(rows: Rows) -> Dict:
    import statistics
    t0 = time.perf_counter()
    gemm = calibrate_gemm()
    t_gemm = time.perf_counter() - t0
    t0 = time.perf_counter()
    gemv = calibrate_gemv()
    t_gemv = time.perf_counter() - t0
    t0 = time.perf_counter()
    net = calibrate_allreduce()
    t_net = time.perf_counter() - t0
    total = t_gemm + t_gemv + t_net
    out = {
        "gemm": {"wall_s": t_gemm,
                 "eff_flops": statistics.median(gemm)},
        "gemv": {"wall_s": t_gemv, "eff_bps": statistics.median(gemv)},
        "allreduce": {"wall_s": t_net, **net},
        "total_s": total,
    }
    rows.add("calibration/total_s", total * 1e6,
             f"gemm={t_gemm:.2f}s gemv={t_gemv:.2f}s net={t_net:.2f}s "
             f"eff_flops={out['gemm']['eff_flops']:.3e} "
             f"(paper: 1022s for 3 GPU types)")
    save_json("calibration.json", out)
    return out
