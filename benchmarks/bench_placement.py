"""Paper Figs 9 & 10 — offline throughput + online latency across placement
algorithms (ShuntServe DP+beam vs HexGen-genetic vs AlpaServe-DP vs
vLLM-even), evaluated through the same simulator on the paper's cluster."""

from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks.common import (Rows, calibrate_sim_efficiency,
                               effective_instances, full_mode,
                               paper_inventory, save_json)
from repro.cluster import ClusterSim, FTConfig, azure_conversation_like
from repro.configs import get_config
from repro.core import populate_cluster
from repro.core.baselines import alpaserve_dp, hexgen_genetic, vllm_even


def plans_for(spec, insts, inv, beam_k=3):
    shunt = populate_cluster(spec, inv, insts, 763, 232, beam_k=beam_k)
    return {
        "shuntserve": shunt,
        "hexgen": hexgen_genetic(spec, inv, insts, 763, 232,
                                 pop_size=16 if full_mode() else 10,
                                 generations=20 if full_mode() else 8,
                                 seed=0),
        "alpaserve": alpaserve_dp(spec, inv, insts, 763, 232),
        "vllm": vllm_even(spec, inv, insts, 763, 232),
    }


PAPER_SHUNT_RPS = {"llama-3.1-70b": 1.53, "qwen3-32b": 4.59}  # §7.1.2


def run(rows: Rows) -> Dict:
    insts = effective_instances()
    inv = paper_inventory()
    out: Dict = {"offline": {}, "online": {}}
    for arch, rate_online, dur_off in (("llama-3.1-70b", 0.7, 300),
                                       ("qwen3-32b", 2.4, 300)):
        spec = get_config(arch).to_modelspec()
        plans = rows.timed(f"placement/{arch}/search_all",
                           lambda: plans_for(spec, insts, inv),
                           lambda p: f"pipes=" + "/".join(
                               str(len(v.pipelines))
                               for v in p.values()))
        # one-time calibration of the roofline->achieved serving efficiency
        # against the paper's measured ShuntServe throughput (so absolute
        # scales match the paper; ratios come from our model)
        eff = calibrate_sim_efficiency(spec, plans["shuntserve"].pipelines,
                                       PAPER_SHUNT_RPS[arch])
        # Fig 9: offline throughput (saturated for the whole window)
        reqs_off = azure_conversation_like(duration_s=dur_off,
                                           rate_rps=4.67 * 4, seed=0)
        off = {}
        for name, plan in plans.items():
            if not plan.pipelines:
                off[name] = 0.0
                continue
            sim = ClusterSim(spec, plan.pipelines, FTConfig(use_spot=True),
                             efficiency=eff)
            off[name] = sim.run(reqs_off, duration_s=dur_off,
                                offline=True).rps
        out["offline"][arch] = off
        base = max(off["hexgen"], off["alpaserve"], off["vllm"], 1e-9)
        rows.add(f"placement_offline/{arch}/shuntserve_rps",
                 off["shuntserve"] * 1e6,
                 f"x{off['shuntserve']/base:.2f} vs best baseline "
                 f"(hexgen={off['hexgen']:.2f} alpa={off['alpaserve']:.2f} "
                 f"vllm={off['vllm']:.2f} rps)")
        # Fig 10: online latency below saturation
        reqs_on = azure_conversation_like(duration_s=600,
                                          rate_rps=rate_online, seed=1)
        on = {}
        for name, plan in plans.items():
            if not plan.pipelines:
                continue
            sim = ClusterSim(spec, plan.pipelines, FTConfig(use_spot=True),
                             efficiency=eff)
            res = sim.run(reqs_on, duration_s=600)
            on[name] = {
                "ttft_med": res.percentile("ttft", 0.5),
                "ttft_p90": res.percentile("ttft", 0.9),
                "tpot_med": res.percentile("tpot", 0.5),
                "tpot_p90": res.percentile("tpot", 0.9),
            }
        out["online"][arch] = on
        s = on.get("shuntserve", {})
        rows.add(f"placement_online/{arch}/ttft_med_s",
                 s.get("ttft_med", float("nan")) * 1e6,
                 f"tpot_med={s.get('tpot_med', float('nan')):.4f}s "
                 f"p90ttft={s.get('ttft_p90', float('nan')):.3f}s")
    save_json("placement.json", out)
    return out
