"""Prefix-sharing KV cache — admitted-capacity gain and prefill-token
reduction at a 0.5 share-ratio workload, with byte-identical greedy
outputs, plus cluster-wide prefix warm-up through the tensor store.

Production traffic concentrates on a few hot system prompts. Without
sharing, every request re-prefills its full prompt and books worst-case
blocks for all of it; with the prefix index, a request extending a cached
prefix maps the shared blocks read-only (refcounted), books fresh blocks
only for its divergent suffix, and prefills only that suffix. Two levers,
both measured here on a workload where HALF the prompts open with a common
prefix (share-ratio 0.5, the ISSUE-6 operating point):

  * capacity — at a TIGHT pool, shared blocks are charged once to the
    committed-blocks ledger, so one admit_many call packs more concurrent
    requests into the same bytes;
  * prefill compute — steady-state (index warmed by prior traffic), every
    shared prompt prefills only its suffix; shared tokens / total prompt
    tokens is the fraction of prefill compute eliminated.

check_smoke.py enforces: capacity ratio >= 1.5x no-sharing OR warm
prefill-token reduction >= 0.40, greedy outputs byte-identical with
sharing on vs off across BOTH waves, and at least one pipeline warm-up
through the tensor store (a re-placed pipeline attaches published hot
prefix blocks instead of recomputing them).
"""

from __future__ import annotations

from typing import Dict, List

import jax

from benchmarks.common import Rows, save_json
from repro.cluster.workload import zipf_shared_prompts
from repro.configs import get_config
from repro.models import build_model
from repro.serving import Engine, GlobalServer, ServeRequest, TensorStore

MAX_LEN = 96
BLOCK = 8
SHARE_RATIO = 0.5
PREFIX_LEN = 48          # 6 full blocks
SUFFIX_LEN = 8
MAX_NEW = 6


def _reqs(prompts: List[List[int]], max_new: int = MAX_NEW):
    return [ServeRequest(prompt=list(p), max_new_tokens=max_new)
            for p in prompts]


def _capacity(cfg, params) -> Dict:
    """One admit_many call over a 0.5-share queue at a TIGHT pool: sharing
    charges each hot prefix's blocks once, so the same pool admits more
    concurrent requests. Single common prefix — the capacity lever is the
    ledger, not the index's breadth."""
    prompts = zipf_shared_prompts(48, n_prefixes=1, prefix_len=PREFIX_LEN,
                                  suffix_len=SUFFIX_LEN,
                                  share_ratio=SHARE_RATIO, vocab=cfg.vocab,
                                  seed=7)
    n_blocks = 14 * 8 + 1        # 14 no-share requests' worst case + trash
    out: Dict = {}
    for label, share in (("noshare", False), ("share", True)):
        # wide skip-ahead window: capacity means max packing, and a tight
        # pool rejects many full-cost requests before the cheap shared
        # ones behind them would fit
        eng = Engine(cfg, params, max_batch=48, max_len=MAX_LEN,
                     kv_layout="paged", block_size=BLOCK, n_blocks=n_blocks,
                     prefix_share=share, admit_window=16)
        admitted = eng.admit_many(_reqs(prompts))
        assert eng.bm.check_no_leak()
        out[label] = {"admitted": len(admitted),
                      "prefix_hits": eng.stats.prefix_hits,
                      "shared_tokens": eng.stats.prefix_shared_tokens,
                      "blocks_in_use": eng.bm.blocks_in_use()}
    out["ratio"] = out["share"]["admitted"] / max(out["noshare"]["admitted"],
                                                  1)
    return out


def _drain(eng: Engine, reqs: List[ServeRequest]) -> None:
    queue = list(reqs)
    rounds = 0
    while (queue or eng.active() or eng._pending) and rounds < 10_000:
        if queue:
            adm = eng.admit_many(queue)
            taken = {id(r) for r in adm}
            queue = [r for r in queue if id(r) not in taken]
        eng.step()
        rounds += 1
    assert all(r.done for r in reqs)


def _identity_reduction(cfg, params) -> Dict:
    """Two waves of the same 0.5-share distribution at an UNconstrained
    pool, sharing on vs off. Wave 1 warms the index (donors prefill in
    full); wave 2 is steady state — every shared prompt hits. Outputs must
    be byte-identical across both engines and both waves; the reduction is
    wave-2 shared tokens over wave-2 prompt tokens."""
    # ONE workload split into waves: both waves draw from the same two hot
    # prefixes (drawn once per seed), so wave 2 runs against a warm index
    all_prompts = zipf_shared_prompts(48, n_prefixes=2,
                                      prefix_len=PREFIX_LEN,
                                      suffix_len=SUFFIX_LEN,
                                      share_ratio=SHARE_RATIO,
                                      vocab=cfg.vocab, zipf_a=2.0, seed=13)
    waves = [all_prompts[:24], all_prompts[24:]]
    outputs: Dict[bool, List] = {}
    stats: Dict[bool, Dict] = {}
    for share in (False, True):
        eng = Engine(cfg, params, max_batch=8, max_len=MAX_LEN,
                     kv_layout="paged", block_size=BLOCK,
                     prefix_share=share)
        gen: List = []
        shared_before = 0
        for w, prompts in enumerate(waves):
            if w == len(waves) - 1:
                shared_before = eng.stats.prefix_shared_tokens
            reqs = _reqs(prompts)
            _drain(eng, reqs)
            gen.append([list(r.generated) for r in reqs])
        assert eng.bm.check_no_leak()
        outputs[share] = gen
        last_tokens = sum(len(p) for p in waves[-1])
        stats[share] = {
            "prefix_hits": eng.stats.prefix_hits,
            "cow_copies": eng.stats.cow_copies,
            "shared_tokens": eng.stats.prefix_shared_tokens,
            "warm_reduction": (eng.stats.prefix_shared_tokens
                               - shared_before) / last_tokens}
    return {"identical": outputs[True] == outputs[False],
            "share": stats[True], "noshare": stats[False],
            "warm_reduction": stats[True]["warm_reduction"]}


def _warmup(cfg, params) -> Dict:
    """Cluster path: pipeline A's hot prefix is published to the tensor
    store; a newly-placed pipeline and an interrupt-rebuilt one both warm
    from it instead of recomputing."""
    prompts = zipf_shared_prompts(10, n_prefixes=2, prefix_len=16,
                                  suffix_len=4, share_ratio=1.0,
                                  vocab=cfg.vocab, zipf_a=3.0, seed=3)
    store = TensorStore()
    srv = GlobalServer(cfg, store, max_batch=4, max_len=64,
                       engine_kw={"kv_layout": "paged", "block_size": 4},
                       use_prefix_share=True, prefix_hot_hits=2)
    p0 = srv.add_pipeline(params, ["inst-A"])
    for r in _reqs(prompts, max_new=4):
        p0.queue.append(r)
    srv.run_until_drained()
    publishes = sum(1 for _, kind, _ in srv.events
                    if kind == "prefix_publish")
    p1 = srv.add_pipeline(params, ["inst-B"])      # warms on placement
    srv.interrupt_instance("inst-A")               # rebuild warms again
    warms = sum(1 for _, kind, _ in srv.events if kind == "prefix_warm")
    # the warmed pipeline shares on FIRST contact — no recompute of the
    # published prefix
    probe = ServeRequest(prompt=list(prompts[0][:16]) + [7, 9, 11, 13],
                         max_new_tokens=3)
    p1.queue.append(probe)
    srv.run_until_drained()
    return {"publishes": publishes, "warms": warms,
            "p1_warmups": p1.engine.stats.prefix_warmups,
            "p1_hits_after_warm": p1.engine.stats.prefix_hits}


def run(rows: Rows) -> Dict:
    cfg = get_config("internlm2-1.8b").reduced()
    model = build_model(cfg, remat=False, attn_chunk=0)
    params = model.init(jax.random.PRNGKey(0))
    out: Dict = {}

    cap = _capacity(cfg, params)
    out["capacity"] = cap
    rows.add("prefix_share/capacity", 0.0,
             f"noshare={cap['noshare']['admitted']} "
             f"share={cap['share']['admitted']} ratio={cap['ratio']:.2f}x "
             f"hits={cap['share']['prefix_hits']} "
             f"shared_tokens={cap['share']['shared_tokens']}")

    ident = _identity_reduction(cfg, params)
    out["identity"] = ident
    rows.add("prefix_share/identity", 0.0,
             f"identical={1 if ident['identical'] else 0} "
             f"reduction={ident['warm_reduction']:.3f} "
             f"hits={ident['share']['prefix_hits']} "
             f"cow={ident['share']['cow_copies']}")

    warm = _warmup(cfg, params)
    out["warmup"] = warm
    rows.add("prefix_share/warmup", 0.0,
             f"publishes={warm['publishes']} warms={warm['warms']} "
             f"warmups={warm['p1_warmups']} "
             f"hits_after_warm={warm['p1_hits_after_warm']}")

    save_json("prefix_share", out)
    return out
