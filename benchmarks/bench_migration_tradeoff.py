"""Paper Fig 5 / §5.1 — KV-cache transfer vs recomputation latency across
context lengths, model sizes and device types (analytical, same cost model
the system uses to pick its recovery strategy)."""

from __future__ import annotations

from typing import Dict

from benchmarks.common import Rows, effective_instances, save_json
from repro.core.estimator import Placement, Stage, stage_latencies
from repro.core.modelspec import uniform_decoder


MODELS = {
    # llama-3 family: 3B / 8B / 70B (per-layer basis for 70B, like the paper)
    "llama-3b": uniform_decoder("llama-3b", 28, 3072, 24, 8, 8192, 128256),
    "llama-8b": uniform_decoder("llama-8b", 32, 4096, 32, 8, 14336, 128256),
    "llama-70b": uniform_decoder("llama-70b", 80, 8192, 64, 8, 28672,
                                 128256),
}


def kv_bytes(spec, ctx: int) -> float:
    return sum(l.kv_bytes_per_token(spec.dtype_bytes) for l in spec.layers
               ) * ctx


# KV transfer runs over TCP between nodes with connection setup, per-tensor
# serialization and engine coordination — the paper's Fig-5 measurements are
# far off NIC line rate. Effective bandwidth fraction + fixed setup cost:
TRANSFER_SETUP_S = 1.0
TRANSFER_EFF = 0.25


def run(rows: Rows) -> Dict:
    insts = effective_instances()
    out: Dict = {}
    for inst_name in ("g6.12xlarge", "g6e.xlarge"):   # L4 vs L40S
        inst = insts[inst_name]
        for mname, spec in MODELS.items():
            per_layer = mname == "llama-70b"   # 70B doesn't fit one GPU
            series = []
            for ctx in (1024, 4096, 16384, 65536):
                # recomputation = prefill over the full context
                stages = (Stage(inst, 1, spec.n_layers, first=True,
                                last=True),)
                p = Placement(spec, stages)
                pre, _ = stage_latencies(spec, p, 1, ctx, 1)
                recompute = sum(pre)
                # transfer = KV bytes over the inter-node network
                nbytes = kv_bytes(spec, ctx)
                transfer = (TRANSFER_SETUP_S + inst.inter_alpha_s
                            + nbytes / (TRANSFER_EFF
                                        * inst.inter_beta_bps))
                if per_layer:
                    recompute /= spec.n_layers
                    transfer /= spec.n_layers
                series.append({"ctx": ctx, "recompute_s": recompute,
                               "transfer_s": transfer})
            out[f"{inst_name}/{mname}"] = series
            # crossover context where transfer starts to win (paper: 64k on
            # L40S for 70B; recompute wins at short/mid contexts)
            cross = next((p["ctx"] for p in series
                          if p["transfer_s"] < p["recompute_s"]), None)
            last = series[-1]
            rows.add(f"migration/{inst_name}/{mname}",
                     last["recompute_s"] * 1e6,
                     f"recompute64k={last['recompute_s']:.3f}s "
                     f"transfer64k={last['transfer_s']:.3f}s "
                     f"crossover_ctx={cross}")
    # decision summary: recomputation wins at short/mid context (paper's
    # conclusion), transfer can win at very long contexts on fast networks
    save_json("migration_tradeoff.json", out)
    return out
