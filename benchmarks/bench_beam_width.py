"""Paper Fig 11 / §7.1.4 — beam width k vs execution time and placement
quality, on the 24-GPU paper cluster and a 15-type heterogeneous cluster."""

from __future__ import annotations

from typing import Dict

from benchmarks.common import (Rows, effective_instances, full_mode,
                               paper_inventory, save_json)
from repro.configs import get_config
from repro.core.placement import PlacementOptimizer


def run(rows: Rows) -> Dict:
    insts = effective_instances()
    out: Dict = {}
    ks = (1, 2, 3, 4, 8) if full_mode() else (1, 2, 3)
    clusters = {"24gpu_3type": paper_inventory()}
    if full_mode():
        clusters["15type"] = {n: 1 for n in insts}
    for cluster_name, inv in clusters.items():
        for arch in ("llama-3.1-70b", "qwen3-32b"):
            spec = get_config(arch).to_modelspec()
            series = []
            for k in ks:
                opt = PlacementOptimizer(spec, inv, insts, 763, 232,
                                         beam_k=k, max_stages=6)
                res = opt.search()
                series.append({"k": k, "wall_s": res.wall_time_s,
                               "rps": res.throughput_rps,
                               "score": res.score,
                               "evaluated": res.evaluated})
                rows.add(f"beam_width/{cluster_name}/{arch}/k{k}",
                         res.wall_time_s * 1e6,
                         f"rps={res.throughput_rps:.3f} "
                         f"evals={res.evaluated}")
            out[f"{cluster_name}/{arch}"] = series
    save_json("beam_width.json", out)
    return out
